"""§Roofline report: aggregate the dry-run artifacts into the per-cell table.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch, shape, mesh): the three terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and per-device memory.  Also ranks cells for the
§Perf hillclimb selection (worst roofline fraction / most collective-bound).
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        name = os.path.basename(path)[: -len(".json")]
        parts = name.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        with open(path) as f:
            d = json.load(f)
        cells.append(d)
    return cells


def rows(cells) -> list[tuple[str, float, str]]:
    out = []
    for c in cells:
        cid = f"{c['arch']}/{c['shape']}/{c['mesh']}"
        if c.get("skipped"):
            out.append((f"roofline/{cid}", 0.0, "SKIP: " + c["skip_reason"][:60]))
            continue
        if not c.get("ok"):
            out.append((f"roofline/{cid}", -1.0, "FAIL: " + c.get("error", "")[:80]))
            continue
        r = c["roofline"]
        dom = r["dominant"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        mem_gb = r["memory"]["peak_bytes"] / 2**30
        out.append(
            (
                f"roofline/{cid}",
                round(frac, 3),
                f"dom={dom} comp={r['compute_s']*1e3:.1f}ms "
                f"mem={r['memory_s']*1e3:.1f}ms coll={r['collective_s']*1e3:.1f}ms "
                f"useful={r['useful_flops_ratio']:.2f} hbm={mem_gb:.1f}GiB",
            )
        )
    return out


def ranking(cells) -> list[tuple[str, float, str]]:
    live = [c for c in cells if c.get("ok") and not c.get("skipped")]

    def frac(c):
        r = c["roofline"]
        b = max(r["compute_s"], r["memory_s"], r["collective_s"])
        return r["compute_s"] / b if b else 0.0

    def coll_share(c):
        r = c["roofline"]
        t = r["compute_s"] + r["memory_s"] + r["collective_s"]
        return r["collective_s"] / t if t else 0.0

    out = []
    worst = sorted(live, key=frac)[:3]
    for c in worst:
        out.append(
            (f"ranking/worst_roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
             round(frac(c), 3), "hillclimb candidate")
        )
    collbound = sorted(live, key=coll_share, reverse=True)[:3]
    for c in collbound:
        out.append(
            (f"ranking/most_collective/{c['arch']}/{c['shape']}/{c['mesh']}",
             round(coll_share(c), 3), "hillclimb candidate")
        )
    return out


def run() -> list[tuple[str, float, str]]:
    cells = load_cells()
    if not cells:
        return [("roofline/no_artifacts", -1.0,
                 "run PYTHONPATH=src python -m repro.launch.dryrun first")]
    return rows(cells) + ranking(cells)


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
