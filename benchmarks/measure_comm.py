"""Measured collective bytes of the actual TPU engines (lowered HLO).

Standalone (sets the fake-device flag before importing jax — run as
``python benchmarks/measure_comm.py`` or via benchmarks.run which spawns it
as a subprocess so the main process keeps seeing one device).

Measures, per engine x mesh, the per-device collective wire bytes of one
block-sparse multiplication, and validates the paper's two claims on the
real compiled programs:
  * PTP (cannon) == OS1 (onesided) A/B volume     [Table 2]
  * 2.5D volume drops vs L=1 and obeys Eq. (7)    [Fig. 3]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=64 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.engine import lower_multiply  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.roofline.hlo_cost import analyze_hlo  # noqa: E402

NB, BS = 16, 8


def measure(mesh, engine, **kw) -> float:
    lowered = lower_multiply(mesh, NB, BS, engine=engine, **kw)
    rep = analyze_hlo(lowered.compile().as_text(), default_group=mesh.size)
    return rep.collective_wire_bytes


def main() -> None:
    rows = []
    for p in (2, 4):
        mesh = make_spgemm_mesh(p=p)
        vols = {e: measure(mesh, e) for e in ("cannon", "onesided", "gather")}
        for e, v in vols.items():
            rows.append((f"measured/{e}/p{p}/bytes_per_dev", round(v), ""))
        assert 0.7 < vols["onesided"] / vols["cannon"] <= 1.01, vols

    base = measure(make_spgemm_mesh(p=4), "onesided")
    for l in (2, 4):
        v = measure(make_spgemm_mesh(p=4, l=l), "twofive", c_layout="scatter")
        rows.append(
            (
                f"measured/twofive_L{l}/p4/bytes_per_dev",
                round(v),
                f"vs L=1 {base:.0f}: x{v / base:.2f}",
            )
        )
        assert v < base, (l, v, base)

    for name, val, note in rows:
        print(f"{name},{val},{note}")


if __name__ == "__main__":
    main()
