"""Measured collective bytes of the actual TPU engines (lowered HLO).

Standalone (sets the fake-device flag before importing jax — run as
``python benchmarks/measure_comm.py`` or via benchmarks.run which spawns it
as a subprocess so the main process keeps seeing one device).

Measures, per engine x mesh, the per-device collective wire bytes of one
block-sparse multiplication, and validates the paper's claims on the real
compiled programs:
  * PTP (cannon) == OS1 (onesided) A/B volume          [Table 2]
  * 2.5D volume drops vs L=1 and obeys Eq. (7)         [Fig. 3]
  * the plan-layer volume model predicts the measured bytes of every
    engine, including non-square (P_R != P_C) grids    [plan_volume]
  * compressed transport cuts a 10%-occupancy multiply's bytes-on-wire
    to <= 35% of the dense-transport bytes, and the sparsity-aware
    volume model (Eq. (7) scaled by panel occupancy, exact bucketed
    capacities) predicts the measured compressed HLO bytes too
    [plan_volume(transport=...), DESIGN.md §3]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=64 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

from repro.core import plan as plan_mod  # noqa: E402
from repro.core.commvolume import plan_volume  # noqa: E402
from repro.core.engine import lower_multiply  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.roofline.hlo_cost import analyze_hlo  # noqa: E402

NB, BS = 16, 8
NB_SPARSE = 32  # the 10%-occupancy compressed-transport scenario


def measure(mesh, engine, nb=NB, **kw) -> float:
    lowered = lower_multiply(mesh, nb, BS, engine=engine, **kw)
    rep = analyze_hlo(lowered.compile().as_text(), default_group=mesh.size)
    return rep.collective_wire_bytes


def modeled(mesh, engine, nb=NB, c_layout="2d", transport=None,
            itemsize=4.0) -> float:
    plan = plan_mod.plan_multiply(mesh, engine)
    return plan_volume(plan, nb, BS, itemsize=itemsize, c_layout=c_layout,
                       transport=transport).total


def sparse_mask(nb: int) -> np.ndarray:
    """Deterministic ~10%-occupancy banded mask ((i + j) % 10 == 0)."""
    i = np.arange(nb)[:, None]
    j = np.arange(nb)[None, :]
    return np.asarray((i + j) % 10 == 0)


def compressed_rows(rows) -> None:
    """Compressed vs dense transport on the 10%-occupancy pattern: the
    wire-byte ratio and the sparsity-aware model's fidelity (the
    acceptance gates of the transport layer)."""
    mask = sparse_mask(NB_SPARSE)
    occ = float(mask.mean())
    for engine, p in (("onesided", 4), ("cannon", 4), ("gather", 4)):
        mesh = make_spgemm_mesh(p=p)
        tr = plan_mod.get_transport(mask, mask, mesh, engine,
                                    mode="compressed")
        dense = measure(mesh, engine, nb=NB_SPARSE)
        comp = measure(mesh, engine, nb=NB_SPARSE, transport=tr)
        m = modeled(mesh, engine, nb=NB_SPARSE, transport=tr)
        ratio = comp / dense
        rows.append(
            (f"measured/{engine}+ct/p{p}/bytes_per_dev", round(comp),
             f"occ {occ:.2f}: x{ratio:.2f} of dense {dense:.0f}; "
             f"model {m:.0f}: x{comp / m:.2f}")
        )
        assert ratio <= 0.35, (engine, ratio, comp, dense)
        assert 0.8 < comp / m < 1.25, (engine, comp, m)


def reduced_wire_rows(rows) -> None:
    """Reduced-precision transport on the compiled programs.

    The claim: bf16 *storage* rides the native wire at half the f32
    bytes (losslessly — nothing re-cast), and an explicit narrow *wire*
    on f32 storage cuts every A/B hop the same way, with
    ``plan_volume(itemsize=..., transport=...)`` modeling the width
    exactly.

    Platform caveat, verified empirically here: XLA:CPU's bf16
    legalization (FloatNormalization) rewrites bf16 collectives as
    ``all-gather(convert<f32>(x))`` + a semantic bf16 round-trip after —
    so on the host platform the bf16 wire measures at f32 width, a
    measurement artifact of the emulation backend (an optimization
    barrier cannot suppress it; it is type legalization, not code
    motion).  bf16 is native on TPU, where the wire stays narrow and the
    strict halving is asserted.  The f8 wire IS measurably narrower on
    CPU (legalized to f16, not f32): it demonstrates on every platform
    that the transport layer's wire cast reaches the compiled collective
    and bytes-on-wire scale with the wire element width."""
    import jax
    import jax.numpy as jnp

    from repro.core import transport as T

    on_tpu = jax.default_backend() == "tpu"
    for engine, p in (("gather", 4), ("cannon", 4), ("onesided", 4)):
        mesh = make_spgemm_mesh(p=p)
        f32 = measure(mesh, engine)
        bf = measure(mesh, engine, dtype=jnp.bfloat16)
        m = modeled(mesh, engine, itemsize=2.0)
        ratio = bf / f32
        rows.append(
            (f"measured/{engine}_bf16/p{p}/bytes_per_dev", round(bf),
             f"x{ratio:.2f} of f32 {f32:.0f}; model {m:.0f}: x{bf / m:.2f}")
        )
        if on_tpu:  # native bf16 collectives: the halving is on the wire
            assert 0.4 <= ratio <= 0.6, (engine, ratio, bf, f32)
            assert 0.8 < bf / m < 1.25, (engine, bf, m)
        else:  # XLA:CPU legalizes bf16 collectives back to f32 width
            assert ratio <= 1.01, (engine, ratio, bf, f32)
            assert 0.8 < bf / (2.0 * m) < 1.25, (engine, bf, m)

    # f8 wire on f32 storage: A/B hops narrow, measurably on EVERY
    # platform (CPU legalizes f8 collectives to f16 = still 2x under
    # f32; TPU ships 1-byte elements = 4x)
    tr = T.PanelTransport("dense", wire="float8_e4m3fn")
    mesh = make_spgemm_mesh(p=4)
    for engine in ("gather", "cannon"):
        f32 = measure(mesh, engine)
        w = measure(mesh, engine, transport=tr)
        m = modeled(mesh, engine, transport=tr)
        rows.append(
            (f"measured/{engine}_f8wire/p4/bytes_per_dev", round(w),
             f"x{w / f32:.2f} of dense {f32:.0f}; model {m:.0f}: "
             f"x{w / m:.2f}")
        )
        assert w / f32 <= 0.6, (engine, w, f32)
        if on_tpu:  # model fidelity at the un-legalized 1-byte wire
            assert 0.8 < w / m < 1.25, (engine, w, m)
        else:  # CPU ships the f8 panels at f16 width — byte-identical
            # to a 2-byte wire, which the model prices as wire=bf16
            m2 = modeled(mesh, engine,
                         transport=T.PanelTransport("dense",
                                                    wire="bfloat16"))
            assert 0.8 < w / m2 < 1.25, (engine, w, m2)


def main() -> None:
    rows = []
    for p in (2, 4):
        mesh = make_spgemm_mesh(p=p)
        vols = {e: measure(mesh, e) for e in ("cannon", "onesided", "gather")}
        for e, v in vols.items():
            m = modeled(mesh, e)
            rows.append(
                (f"measured/{e}/p{p}/bytes_per_dev", round(v),
                 f"model {m:.0f}: x{v / m:.2f}")
            )
            assert 0.8 < v / m < 1.25, (e, p, v, m)
        assert 0.7 < vols["onesided"] / vols["cannon"] <= 1.01, vols

    base = measure(make_spgemm_mesh(p=4), "onesided")
    for l in (2, 4):
        mesh = make_spgemm_mesh(p=4, l=l)
        v = measure(mesh, "twofive", c_layout="scatter")
        m = modeled(mesh, "twofive", c_layout="scatter")
        rows.append(
            (
                f"measured/twofive_L{l}/p4/bytes_per_dev",
                round(v),
                f"vs L=1 {base:.0f}: x{v / base:.2f}; model {m:.0f}",
            )
        )
        assert v < base, (l, v, base)
        assert 0.8 < v / m < 1.25, (l, v, m)

    # non-square grids: the pull engine's virtual depth (L = max/min)
    for p_r, p_c in ((2, 4), (4, 2)):
        mesh = make_spgemm_mesh(p_r=p_r, p_c=p_c)
        v1 = measure(mesh, "onesided")
        vl = measure(mesh, "twofive")
        m1 = modeled(mesh, "onesided")
        ml = modeled(mesh, "twofive")
        rows.append(
            (f"measured/onesided/p{p_r}x{p_c}/bytes_per_dev", round(v1),
             f"model {m1:.0f}: x{v1 / m1:.2f}")
        )
        rows.append(
            (f"measured/twofive_virtL/p{p_r}x{p_c}/bytes_per_dev", round(vl),
             f"vs L=1 {v1:.0f}: x{vl / v1:.2f}; model {ml:.0f}")
        )
        assert 0.8 < v1 / m1 < 1.25, (p_r, p_c, v1, m1)
        assert 0.8 < vl / ml < 1.25, (p_r, p_c, vl, ml)
        assert vl < v1, (p_r, p_c, vl, v1)  # 2.5D wins on non-square too

    compressed_rows(rows)
    reduced_wire_rows(rows)

    for name, val, note in rows:
        print(f"{name},{val},{note}")


if __name__ == "__main__":
    main()
