"""Fig. 2 reproduction: average A/B panel message sizes, strong scaling.

S_A = (N/P_R)(N/V) occ * 8B and S_B = (N/V)(N/P_C) occ * 8B per node count.
Checks the two properties the paper reports:
  * sizes scale ~1/P with the node count (both panel dims shrink),
  * the S-E benchmark's messages are ~6x smaller than the other two at the
    same node count (paper: 5.7x-6.7x) — the explanation offered for its
    outsized one-sided speedup.
"""
from __future__ import annotations

from benchmarks.paper_data import GRIDS
from repro.configs.dbcsr_benchmarks import BENCHMARKS
from repro.core.topology import lcm


def message_sizes_mb(bench_key: str, nodes: int) -> tuple[float, float]:
    b = BENCHMARKS[bench_key]
    p_r, p_c = GRIDS[nodes]
    v = lcm(p_r, p_c)
    s_a = (b.n_rows / p_r) * (b.n_rows / v) * b.occupancy * 8 / 1e6
    s_b = (b.n_rows / v) * (b.n_rows / p_c) * b.occupancy * 8 / 1e6
    return s_a, s_b


def run() -> list[tuple[str, float, str]]:
    rows = []
    for nodes in GRIDS:
        sizes = {k: message_sizes_mb(k, nodes) for k in BENCHMARKS}
        for k, (s_a, s_b) in sizes.items():
            rows.append((f"fig2/{k}/n{nodes}/S_A_MB", round(s_a, 2), ""))
            rows.append((f"fig2/{k}/n{nodes}/S_B_MB", round(s_b, 2), ""))
        se_ratio = (
            (sizes["h2o_dft_ls"][0] + sizes["dense"][0]) / 2 / sizes["s_e"][0]
        )
        rows.append(
            (
                f"fig2/se_smaller_factor/n{nodes}",
                round(se_ratio, 1),
                "paper: 5.7x-6.7x",
            )
        )
    return rows


def check() -> None:
    # ~1/P scaling between 400 and 1296 nodes (both square)
    for k in BENCHMARKS:
        a400, _ = message_sizes_mb(k, 400)
        a1296, _ = message_sizes_mb(k, 1296)
        assert 2.5 < a400 / a1296 < 4.0, (k, a400, a1296)
    # non-square 200-node grid: S_A = 2 S_B (P_C = 2 P_R, V = P_C)
    s_a, s_b = message_sizes_mb("h2o_dft_ls", 200)
    assert abs(s_a / s_b - 2.0) < 1e-6
    # square grids: S_A == S_B in the static model (the paper's 3x comes
    # from run-time occupancy differences between the multiplied operands)
    s_a, s_b = message_sizes_mb("h2o_dft_ls", 729)
    assert abs(s_a / s_b - 1.0) < 1e-6


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
