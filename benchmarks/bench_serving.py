"""Traffic-shaped serving benchmark: MoE dispatch through the SpGEMM stack.

The serving regime is where the paper's communication-reducing machinery
should pay off hardest: tiny per-step compute, latency-bound, and a
dispatch pattern that DRIFTS every batch (every request mix routes tokens
differently).  This bench proves the serving path end to end:

* **dispatch stream** — per-batch (token-block x expert) dispatch masks
  from real router outputs, resolved through the pattern-bucketed
  ``DispatchCache`` (core/envelope.py): the warmed buckets' union
  envelopes route ≥6 drifting batches through one traced program per
  bucket decision (``envelope_traces <= buckets``,
  ``dispatch_hits == batches``, ``drift_retunes == 0``), and — on
  never-repeated masks, the defining property of a drifting stream —
  the warm path beats the per-pattern path (host pattern walk + stack
  generation per batch) by ≥5x;
* **oracle parity** — the ``spgemm`` MoE impl matches the ``dense``
  oracle impl within f32 reorder tolerance (documented: atol 1e-5 /
  rtol 1e-4; measured ~2e-7 at these sizes), with zero dropped tokens on
  both the structural and the covering-envelope path;
* **traffic harness** — the ServingEngine drains Poisson and bursty
  request queues through continuous slot batching with the spgemm
  dispatch installed: p50/p99 per-token decode latency, tokens/s, mean
  occupancy and warm-vs-cold dispatch overhead per arrival process, with
  the compile-once contract asserted across processes (no new programs,
  no new multiply traces after warmup).

NOTE: imported in-process by ``benchmarks/run.py`` — this module must not
set XLA_FLAGS or otherwise touch global process state at import time.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------


def micro_moe_cfg(impl: str = "spgemm"):
    """Hand-rolled micro MoE arch for the dispatch/parity legs."""
    from repro.config import ArchConfig, MoEConfig

    moe = MoEConfig(n_experts=8, top_k=2, d_expert=32, impl=impl,
                    token_block=4)
    return ArchConfig(name="bench-moe", family="llama", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab=128, mlp="swiglu", moe=moe)


def routed_masks(cfg, params, batches: int, n_tokens: int):
    """Per-batch dispatch masks from REAL router outputs (drifting hidden
    states -> drifting masks), plus the hidden states that produced them."""
    from repro.models import moe as M

    e, _ = M.moe_dims(cfg)
    tb = cfg.moe.token_block
    masks, xs = [], []
    for s in range(batches):
        x = jax.random.normal(jax.random.key(1000 + s),
                              (1, n_tokens, cfg.d_model), jnp.float32)
        logits = (x.reshape(-1, cfg.d_model) @ params["router"])
        _, top_e, _ = M.router_probs(cfg.moe, logits.astype(jnp.float32))
        masks.append(np.asarray(M.dispatch_block_mask(top_e, e, tb)))
        xs.append(x)
    return masks, xs


def poisson_arrivals(n: int, mean_gap: float, rng) -> list[int]:
    """Non-decreasing integer arrival steps with exponential gaps."""
    t = np.floor(np.cumsum(rng.exponential(mean_gap, size=n))).astype(int)
    return np.maximum.accumulate(t).tolist()


def bursty_arrivals(n: int, burst: int, gap: int) -> list[int]:
    """Bursts of ``burst`` simultaneous requests every ``gap`` steps."""
    return [(i // burst) * gap for i in range(n)]


# ---------------------------------------------------------------------------
# run.py aggregation legs
# ---------------------------------------------------------------------------


def run() -> list[tuple[str, float, str]]:
    from repro.models import moe as M

    cfg = micro_moe_cfg()
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    masks, _ = routed_masks(cfg, p, 4, 40)
    occ = float(np.mean([m.mean() for m in masks]))
    return [
        ("bench_serving/dispatch/occupancy", round(occ, 3),
         f"E={cfg.moe.n_experts} top{cfg.moe.top_k} tb={cfg.moe.token_block}"
         f"; routed masks, real router"),
    ]


def check() -> None:
    """spgemm impl == dense oracle on a routed micro batch (the coupling
    gate run.py re-asserts on every aggregation)."""
    import dataclasses

    from repro.models import moe as M

    cfg = micro_moe_cfg()
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model),
                          jnp.float32)
    cfg_d = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="dense"))
    yd, _ = M.apply_moe(cfg_d, p, x)
    ys, _, st = M.apply_moe(cfg, p, x, collect_stats=True)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)
    assert int(st["dropped"]) == 0
    # the occupancy artifact and the serving impl share one mask builder
    from benchmarks.moe_spgemm import dispatch_mask

    top_e = jax.random.randint(jax.random.key(2), (32, 2), 0, 8)
    a = np.asarray(M.dispatch_block_mask(top_e, 8, 4))
    b = dispatch_mask(8, 8, 2, 4, jax.random.key(2))
    assert a.shape == b.shape == (8, 8)


# ---------------------------------------------------------------------------
# the CI smoke benchmark (BENCH_serving.json)
# ---------------------------------------------------------------------------


def _dispatch_stream_leg(batches: int, reps: int) -> dict:
    """Warm pattern-bucketed dispatch vs the per-pattern retrace path."""
    import functools
    import time

    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.envelope import DispatchCache
    from repro.core.engine import multiply
    from repro.models import moe as M

    cfg = micro_moe_cfg()
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    e, de = M.moe_dims(cfg)
    tb = cfg.moe.token_block
    # every rep times a FRESH chunk of the stream: a drifting workload
    # never shows the same mask twice, so the per-pattern path must redo
    # its host walk + stack generation every batch (its per-pattern LRU
    # can't help), while the warm path takes them all as data
    n_pool = batches * (reps + 1)
    masks, _ = routed_masks(cfg, p, n_pool, 40)
    nb = masks[0].shape[0]

    # the serving-grade bucket cache, warmed over the calibration stream
    cache = DispatchCache(np.eye(e, dtype=bool)).warm(masks)
    plan_mod.clear_cache()
    envs = [cache.resolve(m) for m in masks]
    stats = plan_mod.cache_stats()
    assert stats["dispatch_hits"] == n_pool, stats
    assert stats["dispatch_misses"] == 0, stats
    assert stats["drift_retunes"] == 0, stats

    # one traced dispatch program across the whole drifting stream: the
    # warmed bucket's envelope capacities are the only statics
    # token-block operand blocks are (tb, tb)-shaped here so A@W closes;
    # the full-layer parity leg runs the real (tb, d_model) geometry
    eye = np.eye(e, dtype=bool)
    wb = jax.random.normal(jax.random.key(1), (e, e, tb, de)) / np.sqrt(tb)
    w = B.make_bsm(wb, eye)
    stream = []
    for s, m in enumerate(masks):
        blocks = jax.random.normal(jax.random.key(200 + s),
                                   (nb, e, tb, tb)) / np.sqrt(tb)
        stream.append(B.make_bsm(blocks, m))

    # the warm step is a jitted program per bucket DECISION — exactly how
    # the ServingEngine executes it (the envelope capacities are statics
    # closed over the trace; the concrete mask enters as data, so the warm
    # path never pays the per-call ``env.covers()`` host sync)
    steps: dict = {}

    def step_for(env, dec):
        key = (dec["backend"], dec["capacity"])
        if key not in steps:
            steps[key] = jax.jit(functools.partial(
                lambda a, *, be, cap: multiply(
                    a, w, backend=be, stack_capacity=cap),
                be=dec["backend"], cap=dec["capacity"]))
        return steps[key]

    # correctness: warm path == per-pattern oracle, bit-for-bit mask and
    # allclose values (restricted to the warmup chunk so the oracle's
    # per-pattern LRU never sees the timed chunks)
    for a, (env, dec) in zip(stream[:batches], envs[:batches]):
        got = step_for(env, dec)(a)
        want = multiply(a, w, backend="stacks")
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()),
                                   rtol=1e-5, atol=1e-6)
    stats = plan_mod.cache_stats()
    assert stats["drift_retunes"] == 0, stats

    def env_pass(chunk):
        for i in chunk:
            env, dec = envs[i]
            out = step_for(env, dec)(stream[i])
        jax.block_until_ready(out.blocks)

    def retrace_pass(chunk):
        for i in chunk:
            out = multiply(stream[i], w, backend="stacks")
        jax.block_until_ready(out.blocks)

    # warmup compiles every program level: all warm-step programs (the
    # full env sweep touches every bucket decision) and the retrace
    # path's capacity-bucketed stack programs; each timed rep then runs a
    # disjoint never-seen chunk of the drifting stream
    chunks = [range(r * batches, (r + 1) * batches) for r in range(reps + 1)]
    env_pass(range(n_pool))
    retrace_pass(chunks[0])
    env_traces = len(steps)
    n_buckets = len(cache)
    assert env_traces <= n_buckets, (env_traces, n_buckets)
    ratios, env_best, retrace_best = [], float("inf"), float("inf")
    for chunk in chunks[1:]:
        t0 = time.perf_counter()
        retrace_pass(chunk)
        tr = (time.perf_counter() - t0) / batches
        t0 = time.perf_counter()
        env_pass(chunk)
        te = (time.perf_counter() - t0) / batches
        env_best, retrace_best = min(env_best, te), min(retrace_best, tr)
        ratios.append(tr / te)
    ratio = sorted(ratios)[len(ratios) // 2]
    return {
        "batches": n_pool,
        "buckets": n_buckets,
        "bucket_stats": cache.stats(),
        "envelope_traces": env_traces,
        "dispatch_hits": int(stats["dispatch_hits"]),
        "drift_retunes": int(stats["drift_retunes"]),
        "warm_per_batch_ms": env_best * 1e3,
        "retrace_per_batch_ms": retrace_best * 1e3,
        "warm_dispatch_ratio": ratio,
        "stream_occupancy": float(np.mean([m.mean() for m in masks])),
    }


def _parity_leg(batches: int) -> dict:
    """spgemm vs dense oracle through apply_moe, cold and enveloped."""
    import dataclasses

    from repro.core.envelope import DispatchCache
    from repro.models import moe as M

    cfg = micro_moe_cfg()
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    cfg_d = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="dense"))
    e, _ = M.moe_dims(cfg)
    tb = cfg.moe.token_block
    n_tok = 40

    # warm the envelope from the SAME router the model applies, so the
    # covering path clips nothing
    masks, xs = routed_masks(cfg, p, batches, n_tok)
    cache = DispatchCache(np.eye(e, dtype=bool)).warm(masks)
    max_err, max_err_env, dropped_env = 0.0, 0.0, 0
    for m, x in zip(masks, xs):
        yd, _ = M.apply_moe(cfg_d, p, x)
        ys, _, st = M.apply_moe(cfg, p, x, collect_stats=True)
        assert int(st["dropped"]) == 0
        max_err = max(max_err, float(jnp.abs(ys - yd).max()))
        env, dec = cache.resolve(m)
        spec = M.DispatchSpec(envelope=env, backend=dec["backend"],
                              stack_capacity=dec["capacity"])
        with M.dispatch_scope(spec):
            ye, _, st = M.apply_moe(cfg, p, x, collect_stats=True)
        dropped_env += int(st["dropped"])
        max_err_env = max(max_err_env, float(jnp.abs(ye - yd).max()))
    # documented tolerance: f32 product-reorder noise (gather/segment-sum
    # vs scan accumulation); measured ~2e-7 at these sizes
    assert max_err < 1e-5, max_err
    assert max_err_env < 1e-5, max_err_env
    assert dropped_env == 0, dropped_env
    return {"batches": batches, "max_abs_err_cold": max_err,
            "max_abs_err_enveloped": max_err_env,
            "dropped_enveloped": dropped_env,
            "tolerance": {"atol": 1e-5, "rtol": 1e-4}}


def _traffic_leg(n_requests: int, max_new: int) -> dict:
    """ServingEngine under Poisson and bursty arrival processes."""
    from repro.core.engine import _multiply_reference_jit
    from repro.core.envelope import DispatchCache
    from repro.models import moe as M
    from repro.models import transformer as T
    from repro.configs import get_arch
    from repro.serving.engine import GenerationConfig, ServingEngine

    cfg = get_arch("deepseek_moe_16b").reduced()
    import dataclasses

    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="spgemm"))
    params = T.init_params(cfg, jax.random.key(0))
    batch, plen, max_len = 4, 8, 64
    engine = ServingEngine(
        cfg, params, batch=batch, max_len=max_len,
        gen=GenerationConfig(max_new_tokens=max_new))

    # covering decode-grid envelope resolved through the bucket cache
    e, _ = M.moe_dims(cfg)
    tb = cfg.moe.token_block
    nb = (batch + tb - 1) // tb
    cache = DispatchCache(np.eye(e, dtype=bool), dtype=str(cfg.dtype))
    env, dec = cache.resolve(np.ones((nb, e), bool))
    engine.set_dispatch(M.DispatchSpec(
        envelope=env, backend=dec["backend"],
        stack_capacity=dec["capacity"]))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
               for _ in range(n_requests)]
    processes = {
        "poisson": poisson_arrivals(n_requests, 1.5, rng),
        "bursty": bursty_arrivals(n_requests, batch, 3 * max_new // 4),
    }
    # warm round: compiles the (prefill, decode) pair for this spec
    engine.serve(prompts[:batch])
    traces_warm = int(_multiply_reference_jit._cache_size())
    programs_warm = len(engine._programs)

    out = {}
    for name, arrivals in processes.items():
        res = engine.serve(prompts, arrivals=arrivals)
        assert all(len(r) > 0 for r in res)
        st = engine.last_serve_stats
        decode_ms = [s["wall_s"] * 1e3 for s in st["steps"]
                     if not s["refilled"]]
        refill_ms = [s["wall_s"] * 1e3 for s in st["steps"] if s["refilled"]]
        total_s = sum(s["wall_s"] for s in st["steps"])
        n_tok = sum(len(r) for r in res)
        out[name] = {
            "requests": n_requests,
            "tokens": n_tok,
            "tokens_per_s": n_tok / total_s if total_s else 0.0,
            "p50_token_ms": float(np.percentile(decode_ms, 50))
            if decode_ms else 0.0,
            "p99_token_ms": float(np.percentile(decode_ms, 99))
            if decode_ms else 0.0,
            "p50_refill_ms": float(np.percentile(refill_ms, 50))
            if refill_ms else 0.0,
            "mean_occupancy": float(np.mean(
                [s["occupancy"] for s in st["steps"]])),
            "n_refills": st["n_refills"],
        }
    # compile-once contract: the whole traffic run (two arrival processes,
    # refills, drifting routing) added NO programs and NO multiply traces
    assert len(engine._programs) == programs_warm == 1, engine._programs
    assert int(_multiply_reference_jit._cache_size()) == traces_warm
    out["programs"] = len(engine._programs)
    out["multiply_traces"] = traces_warm
    return out


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    batches = args.batches or (6 if args.smoke else 12)
    reps = 3 if args.smoke else 10
    n_requests = 8 if args.smoke else 24
    max_new = 6 if args.smoke else 16

    dispatch = _dispatch_stream_leg(batches, reps)
    assert dispatch["envelope_traces"] <= dispatch["buckets"]
    assert dispatch["batches"] >= batches
    assert dispatch["dispatch_hits"] == dispatch["batches"]
    assert dispatch["drift_retunes"] == 0
    assert dispatch["warm_dispatch_ratio"] >= 5.0, (
        f"warm pattern-bucketed dispatch must be >=5x over the per-pattern "
        f"retrace path, got {dispatch['warm_dispatch_ratio']:.2f}")

    parity = _parity_leg(batches)
    traffic = _traffic_leg(n_requests, max_new)

    report = {
        "bench": "serving_traffic",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "dispatch": dispatch,
        "parity": parity,
        "traffic": traffic,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"bench/serving/envelope_traces,{dispatch['envelope_traces']},"
          f"{dispatch['buckets']} bucket(s) for {dispatch['batches']} "
          f"drifting batches")
    print(f"bench/serving/warm_dispatch_ratio,"
          f"{dispatch['warm_dispatch_ratio']:.2f},retrace/warm (median)")
    print(f"bench/serving/parity_max_abs_err,{parity['max_abs_err_cold']:.2e},"
          f"spgemm vs dense oracle")
    for name in ("poisson", "bursty"):
        t = traffic[name]
        print(f"bench/serving/{name}/p50_token_ms,{t['p50_token_ms']:.2f},"
              f"occupancy {t['mean_occupancy']:.2f}")
        print(f"bench/serving/{name}/p99_token_ms,{t['p99_token_ms']:.2f},")
        print(f"bench/serving/{name}/tokens_per_s,{t['tokens_per_s']:.1f},")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
    main()
