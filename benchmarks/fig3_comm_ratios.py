"""Fig. 3 reproduction: OS1 / OSL communicated-volume ratios.

Two layers of validation:

1. Analytic: Eq. (7) ratios with the paper's measured S_C/S_{A,B} (2.7 /
   2.1 / 1.0) reproduce the bar heights of Fig. 3 — e.g. at 2704 nodes
   with L=4 the H2O ratio is ~1.5 while Dense reaches ~1.75 (larger S_C
   eats more of the sqrt(L) saving).

2. Empirical: the S_C/S_{A,B} ratio itself is *measured* from filtered
   block-sparse multiplications of scaled benchmark matrices (the fill-in
   of C under each pattern), confirming the ordering
   dense(1.0) < S-E < H2O used in (1).
"""
from __future__ import annotations

import jax

from benchmarks.paper_data import GRIDS, TABLE2_L
from repro.configs.dbcsr_benchmarks import BENCHMARKS, SC_OVER_SAB
from repro.core import bsm as B
from repro.core.commvolume import volume_ratio_os1_over_osl
from repro.core.engine import multiply_reference
from repro.core.topology import make_topology


def analytic_ratios() -> list[tuple[str, float, str]]:
    rows = []
    for bench in BENCHMARKS:
        for nodes, ls in TABLE2_L.items():
            p_r, p_c = GRIDS[nodes]
            for l in ls:
                topo = make_topology(p_r, p_c, l)
                r = volume_ratio_os1_over_osl(topo, 1.0, 1.0, SC_OVER_SAB[bench])
                rows.append((f"fig3/{bench}/n{nodes}/L{l}", round(r, 3), ""))
    return rows


def measured_fill_in(nb: int = 48, bs: int = 8) -> dict[str, float]:
    """S_C/S_{A,B} measured as occupancy(C)/occupancy(A) on scaled matrices."""
    out = {}
    for key, b in BENCHMARKS.items():
        occ = max(b.occupancy, 2.0 / nb)  # keep scaled grids non-degenerate
        a = B.random_bsm(jax.random.key(1), nb=nb, bs=bs, occupancy=occ,
                         pattern=b.pattern)
        c = multiply_reference(a, a, threshold=1e-12)
        out[key] = float(c.occupancy()) / max(float(a.occupancy()), 1e-9)
    return out


def run() -> list[tuple[str, float, str]]:
    rows = analytic_ratios()
    fill = measured_fill_in()
    for k, v in fill.items():
        rows.append(
            (f"fig3/measured_fill_in/{k}", round(v, 2),
             f"paper S_C/S_AB={SC_OVER_SAB[k]}")
        )
    return rows


def check() -> None:
    # Fig. 3 ordering: larger S_C/S_AB -> smaller OS1/OSL gain, all in (1, sqrt(L)]
    topo = make_topology(52, 52, 4)
    rs = {k: volume_ratio_os1_over_osl(topo, 1, 1, SC_OVER_SAB[k]) for k in BENCHMARKS}
    assert rs["dense"] > rs["s_e"] > rs["h2o_dft_ls"] > 1.0
    assert all(r <= 2.0 for r in rs.values())
    # measured fill-in reproduces the ordering: dense has no fill-in (1.0),
    # sparse patterns fill in (> 1)
    fill = measured_fill_in()
    assert abs(fill["dense"] - 1.0) < 1e-6
    assert fill["h2o_dft_ls"] > 1.2
    assert fill["s_e"] > 1.0


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
