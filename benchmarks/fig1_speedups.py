"""Fig. 1 context: strong-scaling speedups, PTP -> one-sided 2.5D.

Wall-clock speedups cannot be measured on this container (no cluster); what
we *can* reproduce is the communication-bound speedup estimate implied by
the paper's own numbers: with f = fraction of DBCSR time in the A/B
mpi_waitall (paper §4.1, 2704 nodes) and r = OSL/PTP communicated volume
(Table 2), an Amdahl-type bound gives

    speedup >= 1 / (1 - f * (1 - r))

This is a *lower* bound — the paper's measured 1.80x exceeds it because the
one-sided scheme also removes sender-side synchronization and the pre-shift
(effects beyond volume).  The check asserts our bound stays below the
paper's measurement and reproduces the benchmark ordering.
"""
from __future__ import annotations

from benchmarks.paper_data import BEST_SPEEDUP, COMM_GB, EXEC_S, WAITALL_FRAC_2704


def amdahl_bound(bench: str) -> float:
    f = WAITALL_FRAC_2704[bench]["ptp"]
    cells = COMM_GB[bench][2704]
    best_l = min(k for k in cells if k > 1)
    r = cells[best_l] / cells[1]
    return 1.0 / (1.0 - f * (1.0 - r))


def run() -> list[tuple[str, float, str]]:
    rows = []
    for bench in WAITALL_FRAC_2704:
        paper = EXEC_S[bench][2704]["ptp"] / EXEC_S[bench][2704]["best"]
        ours = amdahl_bound(bench)
        rows.append(
            (
                f"fig1/{bench}/speedup_2704",
                round(paper, 2),
                f"paper measured; volume-Amdahl bound={ours:.2f}",
            )
        )
    # speedup grows with node count (paper's central scaling claim)
    for bench in EXEC_S:
        s = [EXEC_S[bench][n]["ptp"] / EXEC_S[bench][n]["best"]
             for n in (200, 400, 729, 1296, 2704)]
        rows.append(
            (f"fig1/{bench}/speedup_trend", round(s[-1] / s[0], 2),
             f"2704-node over 200-node speedup ratio {[round(x, 2) for x in s]}")
        )
    return rows


def check() -> None:
    for bench in WAITALL_FRAC_2704:
        paper = EXEC_S[bench][2704]["ptp"] / EXEC_S[bench][2704]["best"]
        bound = amdahl_bound(bench)
        assert 1.0 < bound < 2.0
        if bench != "dense":
            # volume bound < measured for the sparse benchmarks (one-sided
            # sync removal adds beyond-volume speedup)
            assert bound <= paper + 0.05, (bench, bound, paper)
        else:
            # Dense falls SHORT of its volume bound — the paper §4.1: the
            # L>1 partial-C handling (CPU-side accumulations, many blocks)
            # offsets the volume gain; we reproduce that ordering instead
            assert paper < bound, (bench, bound, paper)
    h2o = EXEC_S["h2o_dft_ls"][2704]["ptp"] / EXEC_S["h2o_dft_ls"][2704]["best"]
    assert abs(h2o - BEST_SPEEDUP) < 0.01  # the paper's 1.80x headline
    # speedup increases with node count for the comm-dominated benchmark
    # (trend, not strictly monotone — the paper's own 729-node point dips)
    s = [EXEC_S["h2o_dft_ls"][n]["ptp"] / EXEC_S["h2o_dft_ls"][n]["best"]
         for n in (200, 400, 729, 1296, 2704)]
    assert s[-1] == max(s) and s[-1] > s[0], s


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
