"""Sign-iteration dispatch benchmark: fused device-resident sweep vs the
legacy per-op loop.

The purification PR's headline number: a Newton-Schulz sweep must cost ONE
program dispatch (the fused chain step), not the legacy pile — two
``multiply()`` re-entries from replicated arrays, half a dozen eager
algebra dispatches, and a blocking host residual sync.  With the matrix
small enough that compute is negligible, per-sweep wall time IS dispatch
overhead, so the sweep measures

  * per-sweep wall time (and sweeps/sec) of both modes, steady-state,
  * the fused/legacy dispatch-overhead ratio (must be >= 5x),
  * fused-vs-legacy numerical parity (residual traces to 1e-5),
  * the plan-layer chain counters: a 10-sweep iteration reuses one
    compiled step (chain_hits) and builds at most one multiply program
    per distinct shape (builds).

Results go to BENCH_signiter.json (the second CI perf-trajectory series,
next to BENCH_local_mm.json; ``--smoke`` in the workflow).

    python benchmarks/bench_signiter.py [--smoke] [--out BENCH_signiter.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bsm as B  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.signiter import (  # noqa: E402
    sign_iteration,
    sign_iteration_legacy,
)
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402

THRESHOLD = 1e-8
FILTER_EPS = 1e-7


def _per_sweep_s(run, sweeps: int, reps: int) -> float:
    run()  # warm-up: compile + fill the plan/chain caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        best = min(best, (time.perf_counter() - t0) / sweeps)
    return best


def _make_fused_steady(x, mesh, sweeps: int, **kw):
    """One steady-state fused run: `sweeps` dispatches of the chain-step
    program, matrices already device-resident (the chain boundaries —
    shard at entry, gather at exit — are one-time costs, reported
    separately).  The chain resets each call: the timed trajectory is the
    convergent one the legacy loop also walks."""
    from repro.core.signiter import _scale_to_unit_spectrum, get_sweep_program

    sx = B.shard_bsm(_scale_to_unit_spectrum(x), mesh)
    ident = B.shard_bsm(B.identity(x.nb_r, x.bs_r, x.dtype), mesh)
    sweep = get_sweep_program(sx, mesh, **kw)

    def run():
        st = (sx.blocks, sx.mask, sx.norms)
        for _ in range(sweeps):
            out = sweep(st[0], st[1], st[2], ident.blocks, ident.mask)
            st = out[:3]
        jax.block_until_ready(out)

    return run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--nb", type=int, default=None)
    ap.add_argument("--bs", type=int, default=None)
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--engine", default="onesided")
    ap.add_argument("--out", default="BENCH_signiter.json")
    args = ap.parse_args()

    nb = args.nb or 8
    bs = args.bs or (4 if args.smoke else 8)
    reps = args.reps or (5 if args.smoke else 10)
    sweeps = args.sweeps
    mesh = make_spgemm_mesh(p=2)

    x = B.random_bsm(jax.random.key(0), nb=nb, bs=bs, occupancy=0.5,
                     pattern="banded", symmetric=True)
    kw = dict(mesh=mesh, engine=args.engine, threshold=THRESHOLD,
              filter_eps=FILTER_EPS, max_iter=sweeps, tol=0.0)

    # ---- numerical parity (tol=0 -> both run exactly `sweeps` sweeps) ----
    _, st_legacy = sign_iteration_legacy(x, **kw)
    plan_mod.clear_cache()
    _, st_fused = sign_iteration(x, mode="fused", sync_every=sweeps, **kw)
    stats = plan_mod.cache_stats()
    np.testing.assert_allclose(
        st_fused.residual_trace, st_legacy.residual_trace, rtol=1e-5, atol=1e-7
    )
    parity = float(np.max(np.abs(
        np.asarray(st_fused.residual_trace)
        - np.asarray(st_legacy.residual_trace)
    )))

    # ---- per-chain cache counters: one step program for the whole run ----
    assert stats["builds"] <= 1, stats
    assert stats["chain_misses"] == 1, stats
    assert stats["chain_hits"] == sweeps - 1, stats

    # ---- dispatch overhead (steady-state; compute is negligible) ---------
    # legacy pays its whole pile every sweep (re-shard, 2 multiply
    # re-entries, eager algebra, residual sync); the fused chain pays one
    # program dispatch per sweep plus one-time chain boundaries.  The two
    # sides are timed back-to-back per rep (paired, median-of-ratios) so
    # shared machine noise cancels out of the headline ratio.
    legacy_run = lambda: sign_iteration_legacy(x, **kw)  # noqa: E731
    fused_run = _make_fused_steady(
        x, mesh, sweeps, engine=args.engine,
        threshold=THRESHOLD, filter_eps=FILTER_EPS, backend="jnp",
    )
    legacy_run(), fused_run()  # warm-up: compile + fill every cache
    legacy_best, fused_best = float("inf"), float("inf")
    pair_ratios = []
    for _ in range(reps):
        t0 = time.perf_counter()
        legacy_run()
        tl = (time.perf_counter() - t0) / sweeps
        t0 = time.perf_counter()
        fused_run()
        tf = (time.perf_counter() - t0) / sweeps
        legacy_best, fused_best = min(legacy_best, tl), min(fused_best, tf)
        pair_ratios.append(tl / tf)
    legacy_s, fused_s = legacy_best, fused_best
    chain_s = _per_sweep_s(
        lambda: sign_iteration(x, mode="fused", sync_every=sweeps, **kw),
        sweeps, reps,
    )
    ratio = sorted(pair_ratios)[len(pair_ratios) // 2]
    stats = plan_mod.cache_stats()

    report = {
        "bench": "signiter_dispatch",
        "backend": jax.default_backend(),
        "engine": args.engine,
        "nb": nb,
        "bs": bs,
        "sweeps": sweeps,
        "threshold": THRESHOLD,
        "filter_eps": FILTER_EPS,
        "legacy_per_sweep_ms": legacy_s * 1e3,
        "fused_per_sweep_ms": fused_s * 1e3,
        "fused_chain_per_sweep_ms": chain_s * 1e3,
        "legacy_sweeps_per_s": 1.0 / legacy_s,
        "fused_sweeps_per_s": 1.0 / fused_s,
        "dispatch_overhead_ratio": ratio,
        "paired_ratios": pair_ratios,
        "chain_ratio": legacy_s / chain_s,
        "residual_parity_max_abs": parity,
        "cache": stats,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"bench/signiter/legacy_per_sweep_ms,{legacy_s * 1e3:.3f},")
    print(f"bench/signiter/fused_per_sweep_ms,{fused_s * 1e3:.3f},steady-state dispatch")
    print(f"bench/signiter/fused_chain_per_sweep_ms,{chain_s * 1e3:.3f},incl. chain boundaries")
    print(f"bench/signiter/overhead_ratio,{ratio:.1f},"
          f"legacy/fused (median of {reps} paired reps)")
    print(f"bench/signiter/parity,{parity:.2e},max |residual diff|")
    print(f"bench/signiter/cache,{stats},")
    print(f"wrote {args.out}")
    assert ratio >= 5.0, (
        f"fused sweep must cut dispatch overhead >= 5x, got {ratio:.1f}x"
    )


if __name__ == "__main__":
    main()
