"""Autotuner benchmark: engine="auto" vs the static (engine, L) oracle
on the application-pattern corpus.

The tuning PR's headline numbers, on CP2K-shaped inputs (banded DFT
chain, exponential decay, Zipf hub rows — ``repro.tuner.corpus``):

  * **oracle match** — the tuner's pick must land within 10% of the
    measured-best candidate on EVERY corpus entry (same candidate, or a
    statistical tie);
  * **worst-case avoidance** — on at least one entry the worst static
    ``(engine, L)`` choice (what a hardcoding caller could have shipped)
    must cost >= 1.2x the tuned choice: this is the paper's point that
    the winning variant is workload-dependent, so a fixed choice loses
    somewhere;
  * **warm-database resolution** — re-resolving every entry with the
    persisted tuning DB performs ZERO timed trials
    (``plan.cache_stats()['tuner_trials'] == 0``).

Results go to BENCH_tuner.json (third CI perf-trajectory series) and the
measured winners to the tuning-DB file (uploaded as a CI artifact, the
warm-start for later runs).

    python benchmarks/bench_tuner.py [--smoke] [--out BENCH_tuner.json]
                                     [--db tuning_db.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro import tuner  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.distribute import product_counts  # noqa: E402
from repro.core.engine import multiply, multiply_reference  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.tuner.corpus import corpus  # noqa: E402
from repro.tuner.measure import measure_candidates  # noqa: E402
from repro.tuner.model import enumerate_candidates  # noqa: E402

THRESHOLD = 1e-6


def bench_entry(entry, mesh, reps: int, db_path: str) -> dict:
    # fresh plan-layer state per entry, then ONE warm world for both the
    # oracle table and the tuner's own trials: comparing a cold-compile
    # measurement against a warm one would only measure jit state
    plan_mod.clear_cache()
    tuner.set_default_db(db_path)
    a, b = entry.build()
    feats = tuner.featurize(a, b, THRESHOLD)
    am, bm = np.asarray(a.mask, bool), np.asarray(b.mask, bool)
    ok = am[:, :, None] & bm[None, :, :]
    counts = product_counts(am, bm)

    # measured oracle over the full candidate space: two passes, min-
    # merged (the first also compiles and warms every program the tuner
    # will re-time; the min filters one-off scheduler noise).  `counts`
    # puts the block->device assignment variants in the oracle space too
    # — the same space autotune ranks, so its pick is always in the table
    cands = enumerate_candidates(mesh, feats, ok=ok, counts=counts)
    table: dict[str, float] = {}
    for _ in range(2):
        trials = measure_candidates(a, b, mesh, cands, threshold=THRESHOLD,
                                    reps=reps)
        for t in trials:
            if t.ok:
                table[t.candidate.label] = min(
                    t.seconds, table.get(t.candidate.label, float("inf")))
    # the tuner's own resolution (fresh decision, full candidate space,
    # recorded into the DB for the warm phase)
    db = tuner.get_default_db()
    keys_before = set(db.records)
    before = plan_mod.cache_stats()
    dec = tuner.autotune(a, b, mesh, threshold=THRESHOLD,
                         top_k=len(cands), reps=reps)
    stats = plan_mod.cache_stats()
    auto_label = dec.label.split("[")[0]
    # the tuner's trials (persisted in its DB record) are one more
    # measurement pass over the same warm programs — min-merge them so
    # both sides of the oracle comparison use the best available estimate
    # (no new record = a bucket-collision DB hit: nothing to merge)
    for key in set(db.records) - keys_before:
        for t in db.records[key]["trials"]:
            if not t["error"] and t["label"] in table:
                table[t["label"]] = min(table[t["label"]], t["seconds"])
    best_label = min(table, key=table.get)
    # the static oracle is over (engine, L) with the historical default
    # local backend — exactly the choice a hardcoding caller ships
    static = {lab: s for lab, s in table.items() if lab.endswith("/jnp")}
    worst_static_label = max(static, key=static.get)
    auto_s = table[auto_label]

    # correctness guard: never report numbers off a wrong result
    ref = multiply_reference(a, b, threshold=THRESHOLD)
    got = multiply(a, b, mesh, engine="auto", threshold=THRESHOLD)
    np.testing.assert_allclose(
        np.asarray(got.to_dense()), np.asarray(ref.to_dense()),
        rtol=1e-5, atol=1e-5,
    )

    return {
        "entry": entry.name,
        "kind": entry.kind,
        "nb": entry.nb,
        "bs": entry.bs,
        "product_fill": feats.product_fill,
        "out_fill": feats.out_fill,
        "auto": auto_label,
        "auto_source": dec.source,
        "auto_ms": auto_s * 1e3,
        "oracle_best": best_label,
        "oracle_best_ms": table[best_label] * 1e3,
        "worst_static": worst_static_label,
        "worst_static_ms": static[worst_static_label] * 1e3,
        "vs_oracle": auto_s / table[best_label],
        "worst_over_auto": static[worst_static_label] / auto_s,
        "tuner_trials": stats["tuner_trials"] - before["tuner_trials"],
        "candidates": {lab: s * 1e3 for lab, s in table.items()},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--nb", type=int, default=None)
    ap.add_argument("--bs", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_tuner.json")
    ap.add_argument("--db", default="tuning_db.json",
                    help="tuning-database artifact path")
    args = ap.parse_args()

    nb = args.nb or (8 if args.smoke else 16)
    bs = args.bs or (8 if args.smoke else 16)
    # the timed calls are milliseconds — compile time dominates the bench,
    # so reps are cheap and buy measurement stability
    reps = args.reps or (10 if args.smoke else 20)
    if os.path.exists(args.db):
        os.remove(args.db)  # this bench MEASURES; the warm phase re-reads

    mesh = make_spgemm_mesh(p=2)
    entries = corpus(nb=nb, bs=bs, smoke=args.smoke)
    results = [bench_entry(e, mesh, reps, args.db) for e in entries]

    # warm phase: a "fresh process" resolving from the persisted DB must
    # perform zero timed trials on every corpus entry
    plan_mod.clear_cache()
    tuner.set_default_db(args.db)
    for entry in entries:
        a, b = entry.build()
        tuner.autotune(a, b, mesh, threshold=THRESHOLD)
    warm = plan_mod.cache_stats()
    assert warm["tuner_trials"] == 0, warm
    assert warm["tuner_hits"] == len(entries), warm

    # acceptance: oracle match on EVERY entry, worst-static >= 1.2x
    # somewhere (the workload-dependence the paper demonstrates)
    for r in results:
        assert r["vs_oracle"] <= 1.10, r
    spread = max(r["worst_over_auto"] for r in results)
    assert spread >= 1.2, [
        (r["entry"], r["worst_over_auto"]) for r in results]

    report = {
        "bench": "tuner_corpus",
        "mesh": {"r": 2, "c": 2},
        "threshold": THRESHOLD,
        "reps": reps,
        "entries": results,
        "warm_db": {"tuner_trials": warm["tuner_trials"],
                    "tuner_hits": warm["tuner_hits"],
                    "records": len(tuner.get_default_db() or ())},
        "max_worst_over_auto": spread,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"{'entry':>18} {'fill':>6} {'auto':>18} {'ms':>8} "
          f"{'oracle':>18} {'vs':>5} {'worst/auto':>10}")
    for r in results:
        print(f"{r['entry']:>18} {r['product_fill']:>6.3f} "
              f"{r['auto']:>18} {r['auto_ms']:>8.3f} "
              f"{r['oracle_best']:>18} {r['vs_oracle']:>5.2f} "
              f"{r['worst_over_auto']:>10.2f}")
    print(f"warm db: {warm['tuner_hits']} hits, 0 trials "
          f"-> wrote {args.out} + {args.db}")


if __name__ == "__main__":
    main()
