"""MXU tile-shape sweep for the tiled pallas SpGEMM kernel.

The tiling PR's headline claim: staging (tm, tk, tn) MXU tiles through
VMEM with an f32 accumulator lets the pallas backend handle atomic
blocks whose whole-block working set cannot stage at all, and picks
tile shapes that keep the MXU fed instead of spilling.  This bench
records, per (block shape, dtype):

  * the analytic ``local_stage_cost`` of whole-block staging vs the best
    explicit tile (the tuner's own ranking signal) — whole-block staging
    of a 1024^3 f32 block needs ~24 MiB of VMEM against a 16 MiB budget,
    so its effective cost is infinite and the model speedup is reported
    as ``inf``;
  * an interpret-mode numerics check on small shapes (tiled == oracle),
    so the sweep never reports a ranking off a wrong kernel;
  * compiled wall time per tile candidate when running on real TPU
    hardware (``jax.default_backend() == "tpu"``) — the >= 1.5x
    wall-clock gate is a HARDWARE gate: interpret-mode pallas timing
    measures the Python emulator, not the kernel, so under ``--smoke``
    /CI the gate is asserted on the model's effective-cost ratio
    (infinite at the VMEM wall, hence trivially passed) and the
    wall-clock column is left null.

Results go to BENCH_kernel_tiles.json (picked up by the BENCH_*.json
wildcard of ``benchmarks/run.py --summary-only``).

    python benchmarks/bench_kernel_tiles.py [--smoke] [--out BENCH_kernel_tiles.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.local_mm import local_filtered_mm, local_stage_cost  # noqa: E402
from repro.kernels.block_spgemm import (  # noqa: E402
    VMEM_BUDGET_BYTES,
    tile_candidates,
    tile_working_set_bytes,
)

GATE_SPEEDUP = 1.5


def _time(fn, *args, reps: int) -> float:
    out = fn(*args)  # warm-up (compile)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _mats(seed, ni, nk, nj, bs, occupancy, dtype):
    ka, kb, km = jax.random.split(jax.random.key(seed), 3)
    a = (jax.random.normal(ka, (ni, nk, bs, bs)) / np.sqrt(bs)).astype(dtype)
    b = (jax.random.normal(kb, (nk, nj, bs, bs)) / np.sqrt(bs)).astype(dtype)
    am = jax.random.uniform(km, (ni, nk)) < occupancy
    bm = jax.random.uniform(jax.random.fold_in(km, 1), (nk, nj)) < occupancy
    an = jnp.where(am, 1.0, 0.0)
    bn = jnp.where(bm, 1.0, 0.0)
    return a, am, an, b, bm, bn


def numerics_row(bs: int, dtype: str, interpret: bool) -> dict:
    """Tiled vs whole-block vs jnp oracle on one small shape."""
    args = _mats(7, 2, 3, 2, bs, 0.6, dtype)
    want, want_m = local_filtered_mm(*args, backend="jnp")
    tiles = tile_candidates(bs, bs, bs, np.dtype(dtype), interpret=interpret)
    tol = 1e-5 if dtype == "float32" else 2e-2
    errs = {}
    for tile in tiles:
        got, got_m = local_filtered_mm(*args, backend="pallas", tile=tile,
                                       interpret=interpret)
        assert bool(jnp.all(got_m == want_m))
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        assert err < tol, (tile, err, tol)
        errs["default" if tile is None else "x".join(map(str, tile))] = err
    return {"bs": bs, "dtype": dtype, "n_tiles": len(tiles),
            "max_abs_err": errs, "tol": tol}


def model_row(bs: int, dtype: str, fill: float, cap: int) -> dict:
    """Analytic whole-block vs best-tile ranking at one block shape."""
    whole_ws = tile_working_set_bytes(bs, bs, bs, None, np.dtype(dtype))
    whole = local_stage_cost(4, 4, 4, bs, bs, bs, fill=fill,
                             backend="pallas", dtype=dtype, capacity=cap)
    best_tile, best = None, whole
    for tile in tile_candidates(bs, bs, bs, np.dtype(dtype), interpret=False):
        if tile is None:
            continue
        lc = local_stage_cost(4, 4, 4, bs, bs, bs, fill=fill,
                              backend="pallas", dtype=dtype, tile=tile,
                              capacity=cap)
        if lc.effective < best.effective:
            best_tile, best = tile, lc
    speedup = (float("inf") if not whole.feasible
               else whole.effective / best.effective)
    return {
        "bs": bs,
        "dtype": dtype,
        "whole_block_ws_bytes": whole_ws,
        "vmem_budget_bytes": VMEM_BUDGET_BYTES,
        "whole_block_feasible": whole.feasible,
        "whole_effective": None if not whole.feasible else whole.effective,
        "best_tile": list(best_tile) if best_tile else None,
        "best_effective": best.effective,
        "model_speedup": None if speedup == float("inf") else speedup,
        "model_speedup_inf": speedup == float("inf"),
    }


def hardware_row(bs: int, dtype: str, reps: int) -> dict:
    """Compiled wall time per tile candidate (TPU only)."""
    args = _mats(11, 2, 2, 2, bs, 1.0, dtype)
    rows = {}
    for tile in tile_candidates(bs, bs, bs, np.dtype(dtype), interpret=False):
        ws = tile_working_set_bytes(bs, bs, bs, tile, np.dtype(dtype))
        if ws > VMEM_BUDGET_BYTES:
            continue  # would fail to stage: the model already says so
        fn = jax.jit(lambda *xs, t=tile: local_filtered_mm(
            *xs, backend="pallas", tile=t))
        key = "default" if tile is None else "x".join(map(str, tile))
        rows[key] = _time(fn, *args, reps=reps) * 1e3
    return {"bs": bs, "dtype": dtype, "wall_ms": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_kernel_tiles.json")
    args = ap.parse_args()

    on_tpu = jax.default_backend() == "tpu"
    interpret = not on_tpu
    reps = args.reps or (3 if args.smoke else 20)

    # numerics first: no ranking off a wrong kernel
    num_shapes = [(8, "float32"), (16, "float32"), (16, "bfloat16")]
    if not args.smoke:
        num_shapes += [(32, "float32"), (32, "bfloat16")]
    numerics = [numerics_row(bs, dt, interpret) for bs, dt in num_shapes]

    # the analytic ranking the tuner searches over, incl. the VMEM wall
    model_shapes = [(256, "float32"), (512, "float32"), (512, "bfloat16"),
                    (1024, "float32"), (1024, "bfloat16")]
    model = [model_row(bs, dt, fill=0.5, cap=8) for bs, dt in model_shapes]

    hardware = []
    if on_tpu and not args.smoke:
        hardware = [hardware_row(bs, dt, reps)
                    for bs, dt in [(256, "float32"), (256, "bfloat16")]]

    # the gate: on at least one large-block shape the tiled kernel beats
    # whole-block staging by >= GATE_SPEEDUP.  At bs=1024 f32 whole-block
    # staging is VMEM-infeasible outright, so the model ratio is infinite.
    best = max((float("inf") if r["model_speedup_inf"]
                else (r["model_speedup"] or 0.0)) for r in model)
    gate_pass = best >= GATE_SPEEDUP
    assert gate_pass, f"tiled/whole model speedup {best} < {GATE_SPEEDUP}"

    report = {
        "bench": "kernel_tile_sweep",
        "backend": jax.default_backend(),
        "interpret": interpret,
        "numerics": numerics,
        "model": model,
        "hardware": hardware,
        "gate": {
            "threshold": GATE_SPEEDUP,
            "best_model_speedup": None if best == float("inf") else best,
            "best_model_speedup_inf": best == float("inf"),
            "pass": gate_pass,
            "wall_clock_gated_on": "tpu hardware only (interpret timing "
                                   "measures the emulator, not the kernel)",
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"{'bs':>6} {'dtype':>9} {'whole ws MiB':>13} {'feasible':>9} "
          f"{'best tile':>14} {'speedup':>9}")
    for r in model:
        sp = "inf" if r["model_speedup_inf"] else f"{r['model_speedup']:.2f}"
        tile = "x".join(map(str, r["best_tile"])) if r["best_tile"] else "-"
        print(f"{r['bs']:>6} {r['dtype']:>9} "
              f"{r['whole_block_ws_bytes'] / 2**20:>13.1f} "
              f"{str(r['whole_block_feasible']):>9} {tile:>14} {sp:>9}")
    for r in numerics:
        worst = max(r["max_abs_err"].values())
        print(f"numerics bs={r['bs']} {r['dtype']}: {r['n_tiles']} tiles, "
              f"max|err|={worst:.2e} < {r['tol']}")
    print(f"gate: best model speedup {'inf' if best == float('inf') else best} "
          f">= {GATE_SPEEDUP} -> wrote {args.out}")


if __name__ == "__main__":
    main()
