"""Table 1 benchmark matrices + measured SpGEMM wall-clock on scaled grids.

Generates the three benchmark patterns at scaled-down grid sizes (same
occupancy/pattern class as Table 1), measures:
  * block occupancy of A and of C = A*A (fill-in),
  * wall-clock per filtered multiplication (jnp backend, this CPU),
  * effective GFLOP/s of the local multiply,
  * sign-iteration convergence on the H2O-like operator (the application).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.dbcsr_benchmarks import BENCHMARKS
from repro.core import bsm as B
from repro.core.engine import multiply_reference
from repro.core.signiter import sign_iteration

NB, BS = 32, 16  # scaled grid: 512x512 elements


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n, out


def run() -> list[tuple[str, float, str]]:
    rows = []
    for key, bench in BENCHMARKS.items():
        occ = max(bench.occupancy, 2.0 / NB)
        a = B.random_bsm(jax.random.key(7), nb=NB, bs=BS, occupancy=occ,
                         pattern=bench.pattern, symmetric=True)
        dt, c = _time(
            lambda x: multiply_reference(x, x, threshold=1e-9), a
        )
        occ_a = float(a.occupancy())
        occ_c = float(c.occupancy())
        # dense-equivalent flops of the occupied products
        import numpy as np

        ok = np.asarray(a.mask)[:, :, None] & np.asarray(a.mask)[None, :, :]
        flops = 2.0 * ok.sum() * BS**3
        rows.append((f"table1/{key}/occ_A", round(occ_a, 4), f"paper~{bench.occupancy}"))
        rows.append((f"table1/{key}/occ_C", round(occ_c, 4), "fill-in after A*A"))
        rows.append((f"table1/{key}/us_per_mult", round(dt * 1e6, 1), f"{NB}x{NB} blocks of {BS}"))
        rows.append((f"table1/{key}/gflops", round(flops / dt / 1e9, 2), "this CPU, jnp backend"))

    # application: sign iteration on the H2O-like operator
    h = B.random_bsm(jax.random.key(8), nb=16, bs=8, occupancy=0.10,
                     pattern="decay", symmetric=True)
    t0 = time.perf_counter()
    _, stats = sign_iteration(h, threshold=1e-9, filter_eps=1e-7,
                              max_iter=60, tol=1e-6)
    dt = time.perf_counter() - t0
    rows.append(("table1/sign_iter/iterations", stats.iterations,
                 f"converged={stats.converged}"))
    rows.append(("table1/sign_iter/mults", stats.multiplications,
                 "2 per iteration (Eq. 3)"))
    rows.append(("table1/sign_iter/total_s", round(dt, 2), ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
