"""Local SpGEMM occupancy sweep: dense masked einsum vs compacted stacks.

The compaction PR's headline number: local FLOPs and wall time must scale
with *surviving products*, not grid volume.  For each block occupancy the
sweep builds a random filtered pair, compacts the product list through the
plan layer (pattern cache + capacity-bucketed program cache), and records

  * measured FLOPs of both backends via
    ``jax.jit(...).lower().compile().cost_analysis()``,
  * predicted FLOPs from the roofline models
    (``spgemm_dense_flops`` / ``spgemm_stacks_flops``),
  * steady-state wall time per multiply,
  * the plan-layer cache counters (a repeated pattern must be a pure hit).

Results go to BENCH_local_mm.json (the CI perf trajectory,
``--smoke`` in the workflow).

    python benchmarks/bench_local_mm.py [--smoke] [--out BENCH_local_mm.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import plan as plan_mod  # noqa: E402
from repro.core.bsm import random_bsm  # noqa: E402
from repro.core.engine import choose_backend, multiply_reference  # noqa: E402
from repro.core.local_mm import local_filtered_mm, pair_filter  # noqa: E402
from repro.roofline.hlo_cost import (  # noqa: E402
    spgemm_dense_flops,
    spgemm_stacks_flops,
    xla_cost_analysis,
)

THRESHOLD = 1e-3


def _time(fn, *args, reps: int) -> float:
    out = fn(*args)  # warm-up (compile)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def sweep_point(nb: int, bs: int, occupancy: float, reps: int) -> dict:
    a = random_bsm(jax.random.key(0), nb, bs, occupancy=occupancy)
    b = random_bsm(jax.random.key(1), nb, bs, occupancy=occupancy)
    args = (a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)

    dense = jax.jit(
        lambda *xs: local_filtered_mm(*xs, threshold=THRESHOLD, backend="jnp")
    )
    dense_c = dense.lower(*args).compile()
    dense_flops = xla_cost_analysis(dense_c)["flops"]
    dense_ms = _time(dense, *args, reps=reps) * 1e3

    ok = np.asarray(pair_filter(a.mask, a.norms, b.mask, b.norms, THRESHOLD))
    stacks, n = plan_mod.get_product_stacks(ok)
    cube = nb * nb * nb
    if stacks.capacity:
        fn = plan_mod.get_local_compiled(
            nb, nb, nb, bs, bs, bs, jnp.float32,
            backend="stacks", capacity=stacks.capacity,
        )
        stacks_c = fn.lower(a.blocks, b.blocks, stacks).compile()
        stacks_flops = xla_cost_analysis(stacks_c)["flops"]
        stacks_ms = _time(fn, a.blocks, b.blocks, stacks, reps=reps) * 1e3
    else:
        stacks_flops, stacks_ms = 0.0, 0.0

    # correctness guard: the sweep never reports numbers off a wrong result
    want = multiply_reference(a, b, threshold=THRESHOLD, backend="jnp")
    got = multiply_reference(a, b, threshold=THRESHOLD, backend="stacks")
    np.testing.assert_allclose(
        np.asarray(got.to_dense()), np.asarray(want.to_dense()),
        rtol=1e-5, atol=1e-5,
    )

    return {
        "occupancy": occupancy,
        "nb": nb,
        "bs": bs,
        "n_products": n,
        "capacity": stacks.capacity,
        "product_fill": n / cube,
        "auto_backend": choose_backend(a, b, THRESHOLD),
        "dense_flops": dense_flops,
        "stacks_flops": stacks_flops,
        "flops_ratio": stacks_flops / dense_flops if dense_flops else 0.0,
        "predicted_dense_flops": spgemm_dense_flops(nb, nb, nb, bs, bs, bs),
        "predicted_stacks_flops": spgemm_stacks_flops(
            stacks.capacity, bs, bs, bs
        ),
        "dense_ms": dense_ms,
        "stacks_ms": stacks_ms,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--nb", type=int, default=None)
    ap.add_argument("--bs", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_local_mm.json")
    args = ap.parse_args()

    nb = args.nb or (8 if args.smoke else 24)
    bs = args.bs or (16 if args.smoke else 32)
    reps = args.reps or (3 if args.smoke else 20)
    occupancies = [0.05, 0.3] if args.smoke else [0.02, 0.05, 0.1, 0.3, 1.0]

    plan_mod.clear_cache()
    sweep = [sweep_point(nb, bs, occ, reps) for occ in occupancies]

    # repeated pattern: must be a pattern-cache hit, no recompile
    before = plan_mod.cache_stats()
    sweep_point(nb, bs, occupancies[0], reps)
    after = plan_mod.cache_stats()
    repeat = {
        "pattern_hits_delta": after["pattern_hits"] - before["pattern_hits"],
        "builds_delta": after["builds"] - before["builds"],
    }
    assert repeat["pattern_hits_delta"] >= 1, repeat
    assert repeat["builds_delta"] == 0, repeat

    report = {
        "bench": "local_mm_occupancy_sweep",
        "backend": jax.default_backend(),
        "threshold": THRESHOLD,
        "sweep": sweep,
        "repeat_pattern": repeat,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"{'occ':>5} {'fill':>7} {'cap':>6} {'dense MF':>9} "
          f"{'stacks MF':>9} {'ratio':>6} {'dense ms':>9} {'stacks ms':>9}")
    for p in sweep:
        print(
            f"{p['occupancy']:>5} {p['product_fill']:>7.3f} "
            f"{p['capacity']:>6} {p['dense_flops'] / 1e6:>9.2f} "
            f"{p['stacks_flops'] / 1e6:>9.2f} {p['flops_ratio']:>6.3f} "
            f"{p['dense_ms']:>9.3f} {p['stacks_ms']:>9.3f}"
        )
    print(f"repeat pattern: {repeat} -> wrote {args.out}")


if __name__ == "__main__":
    main()
