"""Example: batched serving with prefill + decode against a KV cache.

    python examples/serve_batch.py [--tuning-db tuning_db.json]

Drives the ServingEngine (slot-based batching, greedy + temperature
sampling, EOS early-exit) with a reduced qwen-family model, and verifies
decode consistency: the engine's greedy continuation equals teacher-forced
argmax over a full forward pass.  ``--tuning-db`` binds the tuner database
(as ``repro.launch.serve`` does) so any dispatch decisions resolved during
the run persist; without it the static analytic fallback decides.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.engine import GenerationConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tuning-db", default=None,
                    help="tuning database path (omitted = static fallback)")
    args = ap.parse_args()
    if args.tuning_db:
        from repro import tuner
        from repro.core import plan as plan_mod

        plan_mod.clear_cache()
        tuner.set_default_db(args.tuning_db)

    cfg = get_arch("qwen1.5-4b").reduced()
    params = T.init_params(cfg, jax.random.key(0))

    engine = ServingEngine(
        cfg, params, batch=4, max_len=128,
        gen=GenerationConfig(max_new_tokens=12, temperature=0.0),
    )
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=16).astype(np.int32)
               for _ in range(4)]

    t0 = time.time()
    outs = engine.generate(prompts)
    dt = time.time() - t0
    print(f"4 requests x 12 tokens in {dt:.1f}s (incl. compile)")
    for i, o in enumerate(outs):
        print(f"  req{i}: {o}")

    # consistency oracle: greedy engine output == teacher-forced argmax
    full = np.concatenate([prompts[0], np.asarray(outs[0][:-1], np.int32)])
    x, _ = T.forward(cfg, params, jnp.asarray(full[None]))
    logits = L.logits_matmul(
        cfg, params["embed"], L.apply_norm(cfg, params["final_norm"], x))
    greedy = np.asarray(jnp.argmax(logits[0, len(prompts[0]) - 1 :], -1))
    match = int((greedy[: len(outs[0])] == np.asarray(outs[0])).sum())
    print(f"teacher-forced consistency: {match}/{len(outs[0])} tokens match")
    assert match >= len(outs[0]) - 1  # allow one borderline tie flip
    print("serve_batch OK")


if __name__ == "__main__":
    main()
