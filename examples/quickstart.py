"""Quickstart: distributed block-sparse matrix multiplication (the paper's
core operation) on a fake-device mesh, all three communication engines.

    python examples/quickstart.py

Walks through: building block-sparse matrices (DBCSR-style block grid +
occupation mask + block norms), multiplying them with the Cannon/PTP
baseline, the one-sided OS1 analogue, and the 2.5D engine, with on-the-fly
norm filtering — and verifies all engines agree with the dense result.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bsm as B
from repro.core.engine import multiply, multiply_reference
from repro.launch.mesh import make_spgemm_mesh


def main() -> None:
    key = jax.random.key(0)
    # H2O-DFT-LS-like operator: ~10% block occupancy, exponential decay
    a = B.random_bsm(key, nb=16, bs=16, occupancy=0.10, pattern="decay")
    b = B.random_bsm(jax.random.key(1), nb=16, bs=16, occupancy=0.10,
                     pattern="decay")
    print(f"A: {a.shape} elements, occupancy {float(a.occupancy()):.1%}, "
          f"{int(a.nnz_blocks())} occupied blocks")

    ref = multiply_reference(a, b, threshold=1e-8)
    print(f"C=A*B fill-in: occupancy {float(ref.occupancy()):.1%}")

    # 2D engines on a 2x2 (r, c) grid
    mesh2d = make_spgemm_mesh(p=2)
    for engine in ("cannon", "onesided", "gather"):
        c = multiply(a, b, mesh2d, engine=engine, threshold=1e-8)
        err = float(jnp.abs(c.to_dense() - ref.to_dense()).max())
        print(f"engine={engine:9s} grid=2x2    max|err| = {err:.2e}")

    # the paper's 2.5D engine on an (L=2, 2, 2) mesh
    mesh25 = make_spgemm_mesh(p=2, l=2)
    for layout in ("2d", "scatter"):
        c = multiply(a, b, mesh25, engine="twofive", threshold=1e-8,
                     c_layout=layout)
        err = float(jnp.abs(c.to_dense() - ref.to_dense()).max())
        print(f"engine=twofive   grid=2x2x2 c_layout={layout:7s} "
              f"max|err| = {err:.2e}")

    # on-the-fly filtering: aggressive threshold drops small products
    c_filt = multiply(a, b, mesh25, engine="twofive", threshold=0.5,
                      filter_eps=0.05)
    print(f"filtered multiply: occupancy {float(c_filt.occupancy()):.1%} "
          f"(vs {float(ref.occupancy()):.1%} unfiltered)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
