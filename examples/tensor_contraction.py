"""Blocked sparse tensor contraction: einsum onto the SpGEMM stack.

    python examples/tensor_contraction.py

Walks through the tensor layer (DESIGN.md §10): building a screened
3-index integral tensor (ij|k), contracting it against a 2-index
operator with ``contract("ijk,kl->ijl")`` — which matricizes both
operands onto a tall-skinny block-sparse matrix product and runs the
ordinary distributed SpGEMM, with ``engine="auto"`` letting the tuner
pick engine/depth/backend and persist its decision in a tuning DB —
then keeps a two-step contraction chain device-resident end to end
with ``shard_tensor``.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import tuner
from repro.core import tensor as T
from repro.launch.mesh import make_spgemm_mesh


def main() -> None:
    # screened three-center tensor (ij|k): occupation decays with the
    # spread of the block coordinates, ~10% of blocks survive
    t = T.random_tensor(jax.random.key(0), nbs=(8, 8, 8), bss=8,
                        occupancy=0.10, pattern="decay")
    op = T.random_tensor(jax.random.key(1), nbs=(8, 8), bss=8,
                         occupancy=0.3, pattern="decay")
    print(f"T: shape {t.shape}, {int(t.nnz_blocks())} of "
          f"{np.prod(t.nbs)} blocks occupied "
          f"({float(t.occupancy()):.1%})")

    # the contraction is a matricized SpGEMM: (ij | k) x (k | l) —
    # a (64, 8) x (8, 8) tall-skinny block matrix product underneath
    mesh = make_spgemm_mesh(p=2)
    with tempfile.TemporaryDirectory() as tmp:
        # engine="auto": the tuner measures candidates once, persists
        # the winner, and every later contraction of this pattern
        # resolves from the DB without timing anything
        tuner.set_default_db(os.path.join(tmp, "tuning_db.json"))
        c = T.contract("ijk,kl->ijl", t, op, mesh=mesh, engine="auto",
                       threshold=1e-8)
        ref = T.contract_reference("ijk,kl->ijl", t, op)
        err = float(np.abs(np.asarray(c.to_dense()) - ref).max())
        print(f"contract('ijk,kl->ijl') on 2x2 mesh: max|err| = {err:.2e}")

        # chain two contractions device-resident: shard once, contract
        # twice, gather once — the intermediate never leaves the devices
        op2 = T.random_tensor(jax.random.key(2), nbs=(8, 8), bss=8,
                              occupancy=0.3, pattern="decay")
        st = T.shard_tensor(t, mesh, row_axes=(0, 1), col_axes=(2,))
        s1 = T.shard_tensor(op, mesh, row_axes=(0,), col_axes=(1,))
        s2 = T.shard_tensor(op2, mesh, row_axes=(0,), col_axes=(1,))
        mid = T.contract("ijk,kl->ijl", st, s1, mesh=mesh, engine="auto")
        print(f"intermediate stays sharded: {mid}")
        fin = T.contract("ijl,lm->ijm", mid, s2, mesh=mesh, engine="auto")
        chain_ref = T.contract_reference("ijk,kl,lm->ijm", t, op, op2)
        err = float(np.abs(
            np.asarray(fin.to_tensor().to_dense()) - chain_ref).max())
        print(f"two-step sharded chain:       max|err| = {err:.2e}")
    print("tensor_contraction OK")


if __name__ == "__main__":
    main()
