"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    python examples/train_lm.py [--steps 300]

Uses the public API end to end: arch config (olmo-1b family scaled to
~100M params), synthetic Zipf+Markov data pipeline, AdamW, checkpointing
with auto-resume, on a (2, 2) data x model mesh of fake CPU devices —
the same code path the production launcher (repro.launch.train) runs on
real pods.  Asserts the loss actually drops below the unigram entropy
floor's neighbourhood.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import ShapeConfig
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticLMData, make_global_batch
from repro.launch.mesh import make_mesh
from repro.launch.steps import StepOptions, build_train_step
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.parallel.sharding import batch_spec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=16)
    args = ap.parse_args()

    # ~100M params: olmo family, 8 layers x d768
    cfg = dataclasses.replace(
        get_arch("olmo-1b"), n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab=32768, dtype="float32",
    )
    mesh = make_mesh((2, 2), ("data", "model"))
    shape = ShapeConfig("train", args.seq_len, args.global_batch, "train")
    options = StepOptions(remat="full", loss_chunk=args.seq_len)
    opt = AdamWConfig(lr=3e-4, weight_decay=0.01)

    step_fn, (p_sds, o_sds, _) = build_train_step(cfg, mesh, shape, opt=opt,
                                                  options=options)
    shardings = lambda t: jax.tree.map(lambda x: x.sharding, t)
    params = jax.jit(lambda k: T.init_params(cfg, k),
                     out_shardings=shardings(p_sds))(jax.random.key(0))
    opt_state = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype, device=s.sharding), o_sds)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params on mesh {dict(mesh.shape)}")

    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                      global_batch=args.global_batch))
    spec = batch_spec(mesh, args.global_batch, args.seq_len)

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_")
    mgr = CheckpointManager(ckpt_dir, keep=2, mesh=mesh)

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = make_global_batch(data, step, mesh, spec)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    mgr.save(args.steps, {"params": params, "opt": opt_state})
    dt = time.time() - t0

    print(f"{args.steps} steps in {dt:.0f}s "
          f"({args.steps * args.global_batch * args.seq_len / dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.4f} -> {min(losses[-10:]):.4f}")
    assert min(losses[-10:]) < losses[0] - 1.0, "model failed to learn"
    print(f"checkpoints in {ckpt_dir}: latest step {mgr.latest()}")
    print("train_lm OK")


if __name__ == "__main__":
    main()
