"""End-to-end driver: linear-scaling DFT density-matrix purification.

    python examples/linear_scaling_dft.py [--tuning-db tuning_db.json]

The paper's driving application (CP2K): compute the density matrix
P = 1/2 (I - sign(H - mu I)) of a sparse model Hamiltonian WITHOUT
diagonalization, via the Newton-Schulz sign iteration (Eq. (3)) — two
filtered block-sparse multiplications per iteration on the 2.5D engine.

Runs the device-resident iteration engine (DESIGN.md §5): H is sharded
once at the chain boundary, every sweep is ONE dispatch of one compiled
program (both multiplies + the inter-multiply algebra fused), the
residual stays on the mesh and the host syncs it every ``sync_every``
sweeps.  The plan-layer cache counters printed at the end show the whole
purification compiled exactly one program.

With ``--tuning-db`` the engine is chosen by the pattern-aware autotuner
(``engine="auto"``, DESIGN.md §6): H's banded pattern is featurized, the
Eq. 6/7 model prunes, short trials pick the winner, and the decision
persists — a second run resolves measurement-free from the database.
Without the flag the static 2.5D engine is used as before.

Validates the physics observable trace(P) == number of occupied states
against a dense eigendecomposition, and reports the occupancy trajectory
(the sparsity the filtering maintains — the paper's premise).
"""
import argparse
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from repro import tuner
from repro.core import bsm as B
from repro.core import plan as plan_mod
from repro.core.signiter import density_matrix, trace
from repro.launch.mesh import make_spgemm_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tuning-db", default=None,
                    help="tuning-database path: autotune the engine "
                    "(engine='auto'); omitted = static twofive")
    args = ap.parse_args()
    # sparse model Hamiltonian: banded block structure (near-sighted
    # operator), symmetric, ~10% block occupancy — H2O-DFT-LS-like
    h = B.random_bsm(
        jax.random.key(42), nb=12, bs=8, occupancy=0.10,
        pattern="banded", bandwidth=2, symmetric=True,
    )
    n = h.shape[0]
    dense_h = np.asarray(h.to_dense(), np.float64)
    w = np.linalg.eigvalsh(dense_h)
    mu = float(np.median(w))  # half filling
    n_occ = int((w < mu).sum())
    print(f"H: {n}x{n}, block occupancy {float(h.occupancy()):.1%}, "
          f"{n_occ} states below mu={mu:.4f}")

    if args.tuning_db:
        # autotuned engine on a 2D mesh: the tuner is free to pick the
        # 2.5D pull engine with a *virtual* depth (or not)
        mesh = make_spgemm_mesh(p=2)
        engine = "auto"
    else:
        mesh = make_spgemm_mesh(p=2, l=2)  # static: the 2.5D engine, L=2
        engine = "twofive"
    # shard H once: the whole purification runs on the shards (one
    # compiled sweep per dispatch), P comes back sharded — the only
    # gathers below are the explicit chain-boundary to_dense() calls
    h_sharded = B.shard_bsm(h, mesh)
    plan_mod.clear_cache()
    if args.tuning_db:
        tuner.set_default_db(args.tuning_db)  # after clear_cache (which
        # resets the tuner binding along with every other cache level)
    t0 = time.time()
    p, stats = density_matrix(
        h_sharded, mu, engine=engine,
        threshold=1e-9, filter_eps=1e-8, max_iter=100, tol=1e-6,
        mode="fused", sync_every=4,
    )
    dt = time.time() - t0

    tr = float(trace(p))
    cache = plan_mod.cache_stats()
    print(f"sign iteration: {stats.iterations} iterations "
          f"({stats.multiplications} multiplications, 2/iter per Eq. (3)), "
          f"converged={stats.converged}, {dt:.1f}s")
    print(f"device-resident chain: {stats.host_syncs} host syncs "
          f"(sync_every={stats.sync_every}), cache: "
          f"{cache['builds']} program build(s), "
          f"{cache['chain_hits']} fused-sweep reuses")
    if engine == "auto":
        print(f"autotuned engine: {cache['tuner_trials']} trial(s), "
              f"{cache['tuner_hits']} db/cache hit(s) "
              f"-> {args.tuning_db}")
    assert isinstance(p, B.ShardedBSM)  # P never left the mesh
    # one chain program; extra builds can only be tuner trials (cold DB)
    assert cache["builds"] <= 1 + cache["tuner_trials"], cache
    print(f"trace(P) = {tr:.4f}  (want {n_occ} occupied states)")
    print(f"occupancy trajectory: "
          f"{[f'{o:.0%}' for o in stats.occupancy_trace[:8]]}...")

    pd = np.asarray(p.to_dense(), np.float64)
    idem = np.abs(pd @ pd - pd).max()
    print(f"idempotency |P^2 - P|_max = {idem:.2e} (projector check)")
    assert abs(tr - n_occ) < 0.05, (tr, n_occ)
    assert idem < 5e-3
    print("linear_scaling_dft OK")


if __name__ == "__main__":
    main()
